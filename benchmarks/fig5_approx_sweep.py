"""Paper Fig.5: accuracy + execution time over the (B, s) grid on MNIST,
extended with the embedded-space sweep over m (the second approximation knob).

Claims validated (paper §4.2):
  * accuracy decreases slightly as B grows,
  * accuracy decreases almost monotonically with s, dropping hard s < 0.2,
  * execution time falls roughly like s (kernel evaluations ~ s N^2 / B).

Beyond-paper (repro.approx): for method in (rff, nystrom) sweep the
embedding dimension m at fixed B — accuracy rises with m (approaching the
exact kernel fit) while cost scales with n*m instead of s*(N/B)^2. Emitted
under the same JSON schema (one record per grid point with accuracy and
seconds) as the (B, s) grid.

Selector sweep (repro.approx.selectors): on an rbf + imbalanced-blobs
workload, sweep the *landmark-selection strategy* (uniform / kpp / rls) at
each m. The claim under test is the planner's accuracy-per-byte frontier:
ridge-leverage-score selection matches or beats uniform NMI at every m
(small clusters carry high leverage; uniform sampling starves them), and
``plan(...).frontier()`` must rank the strategies consistently with the
measured sweep.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig,
                        clustering_accuracy, gamma_from_dmax, nmi, plan)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_mnist_like

from .common import Timer, save, table


def _imbalanced_blobs(n: int, d: int, c: int, *, seed: int,
                      power: float = 1.8):
    """Gaussian blobs with power-law cluster sizes: the workload where
    landmark selection matters — a uniform m-sample rarely covers the
    small clusters, while their rows carry high ridge leverage. The
    selection effect lives at m ~ C (borderline rank); past ~2C any
    landmark set spans these blobs and the strategies tie."""
    rng = np.random.default_rng(seed)
    sizes = (1.0 / np.arange(1, c + 1)) ** power
    sizes = np.maximum((sizes / sizes.sum() * n).astype(np.int64), 8)
    sizes[0] += n - sizes.sum()
    y = np.repeat(np.arange(c), sizes).astype(np.int32)
    centers = rng.normal(0.0, 6.0 / np.sqrt(d), size=(c, d))
    x = centers[y] + rng.normal(0.0, 1.0 / np.sqrt(d), size=(len(y), d))
    perm = rng.permutation(len(y))
    return x[perm].astype(np.float32), y[perm]


def run(fast: bool = True):
    n = 4000 if fast else 60000
    n_test = 1000 if fast else 10000
    bs = [1, 2, 4] if fast else [1, 2, 4, 8]
    ss = [0.05, 0.2, 0.5, 1.0] if fast else [0.025, 0.05, 0.1, 0.2, 0.4,
                                             0.7, 1.0]
    x, y = make_mnist_like(n + n_test, seed=0)
    x_tr, y_tr = x[:n], y[:n]
    x_te, y_te = x[n:], y[n:]
    gamma = gamma_from_dmax(jnp.asarray(x_tr[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)

    grid = {}
    rows = []
    for b in bs:
        for s in ss:
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b, s=s,
                                  kernel=spec, seed=0)
            with Timer() as t:
                res = fit_dataset(x_tr, cfg)
            labels = np.asarray(predict(jnp.asarray(x_te), res.state.medoids,
                                        res.state.medoid_diag, spec=spec))
            acc = clustering_accuracy(y_te, labels)
            grid[f"B{b}_s{s}"] = {"B": b, "s": s, "acc": acc,
                                  "seconds": t.seconds}
            rows.append([b, s, f"{acc:.3f}", f"{t.seconds:.2f}s"])

    table("Fig.5 — (B, s) sweep on MNIST-like (test accuracy)",
          ["B", "s", "accuracy", "time"], rows)

    # paper-claim checks
    accs_at_s1 = [grid[f"B{b}_s1.0"]["acc"] for b in bs]
    acc_smin = grid[f"B{bs[0]}_s{ss[0]}"]["acc"]
    acc_s1 = grid[f"B{bs[0]}_s1.0"]["acc"]
    t_smin = grid[f"B{bs[0]}_s{ss[0]}"]["seconds"]
    t_s1 = grid[f"B{bs[0]}_s1.0"]["seconds"]
    print(f"[fig5] acc vs B at s=1: {[f'{a:.3f}' for a in accs_at_s1]} "
          f"(mild decrease expected)")
    print(f"[fig5] s={ss[0]}: acc {acc_smin:.3f} vs s=1 {acc_s1:.3f}; "
          f"time {t_smin:.2f}s vs {t_s1:.2f}s")
    # -- embedded-space sweep: m for rff/nystrom at fixed B ----------------
    b_embed = bs[0]
    ms = [20, 40, 80] if fast else [20, 40, 80, 160, 320]
    embed_grid = {}
    embed_rows = []
    for method in ("rff", "nystrom"):
        for m in ms:
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b_embed,
                                  kernel=spec, seed=0, method=method,
                                  embed_dim=m)
            with Timer() as t:
                res = fit_dataset(x_tr, cfg)
            labels = np.asarray(res.predict(jnp.asarray(x_te)))
            acc = clustering_accuracy(y_te, labels)
            embed_grid[f"{method}_m{m}"] = {"method": method, "B": b_embed,
                                            "m": m, "acc": acc,
                                            "seconds": t.seconds}
            embed_rows.append([method, m, f"{acc:.3f}", f"{t.seconds:.2f}s"])

    table("Fig.5+ — embedding-dim sweep (rff/nystrom, test accuracy)",
          ["method", "m", "accuracy", "time"], embed_rows)

    for method in ("rff", "nystrom"):
        accs = [embed_grid[f"{method}_m{m}"]["acc"] for m in ms]
        print(f"[fig5] {method}: acc over m={ms}: "
              f"{[f'{a:.3f}' for a in accs]} (rise toward exact expected)")

    # -- selector sweep: uniform vs kpp vs rls Nystrom (rbf, blobs) --------
    n_sel = 3000 if fast else 20000
    n_sel_te = 800 if fast else 4000
    c_sel, d_sel = 12, 16
    ms_sel = [12, 24, 48] if fast else [12, 24, 48, 96]
    seeds = range(6)     # Lloyd-seeding noise >> selector effect per seed
    xs_all, ys_all = _imbalanced_blobs(n_sel + n_sel_te, d_sel, c_sel, seed=7)
    xb_tr, yb_tr = xs_all[:n_sel], ys_all[:n_sel]
    xb_te, yb_te = xs_all[n_sel:], ys_all[n_sel:]
    # 4x the heuristic gamma: a more local kernel raises the Gram matrix's
    # effective rank, which is where landmark coverage differentiates.
    gamma_sel = 4.0 * gamma_from_dmax(jnp.asarray(xb_tr[:4096]))
    spec_sel = KernelSpec("rbf", gamma=gamma_sel)

    selector_grid = {}
    sel_rows = []
    for sel in ("uniform", "kpp", "rls"):
        for m in ms_sel:
            nmis, secs = [], []
            for seed in seeds:
                cfg = MiniBatchConfig(n_clusters=c_sel, n_batches=2,
                                      kernel=spec_sel, seed=seed,
                                      method="nystrom", embed_dim=m,
                                      selector=sel)
                with Timer() as t:
                    res = fit_dataset(xb_tr, cfg)
                labels = np.asarray(res.predict(jnp.asarray(xb_te)))
                nmis.append(nmi(yb_te, labels))
                secs.append(t.seconds)
            selector_grid[f"{sel}_m{m}"] = {
                "selector": sel, "m": m, "nmi": float(np.mean(nmis)),
                "nmi_per_seed": nmis, "seconds": float(np.mean(secs))}
            sel_rows.append([sel, m, f"{np.mean(nmis):.3f}",
                             f"{np.mean(secs):.2f}s"])

    table("Fig.5++ — landmark-selector sweep (nystrom, imbalanced blobs, "
          "test NMI)", ["selector", "m", "NMI", "time"], sel_rows)

    rls_vs_unif = [(m, selector_grid[f"rls_m{m}"]["nmi"],
                    selector_grid[f"uniform_m{m}"]["nmi"]) for m in ms_sel]
    print("[fig5] rls vs uniform NMI per m: "
          + "  ".join(f"m={m}: {r:.3f}/{u:.3f}" for m, r, u in rls_vs_unif))

    # planner frontier must rank the strategies the way the sweep measured
    machine = MachineSpec(memory_bytes=16e9, n_processors=8)
    frontier = plan(n_sel, c_sel, machine, d=d_sel,
                    selector="rls").frontier()
    rank = {f"{r['method']}:{r['selector']}": i
            for i, r in enumerate(frontier)}
    mean_nmi = {s: float(np.mean([selector_grid[f"{s}_m{m}"]["nmi"]
                                  for m in ms_sel]))
                for s in ("uniform", "kpp", "rls")}
    frontier_says_rls = rank["nystrom:rls"] < rank["nystrom:uniform"]
    sweep_says_rls = mean_nmi["rls"] >= mean_nmi["uniform"] - 0.01
    print(f"[fig5] frontier rank: {sorted(rank, key=rank.get)}; "
          f"mean NMI {mean_nmi}")

    payload = {"grid": grid,
               "embed_grid": embed_grid,
               "selector_grid": selector_grid,
               "frontier": frontier,
               "claim_acc_drops_with_B": bool(accs_at_s1[-1]
                                              <= accs_at_s1[0] + 0.02),
               "claim_small_s_cheaper": bool(t_smin < t_s1),
               "claim_acc_rises_with_m": bool(
                   embed_grid[f"nystrom_m{ms[-1]}"]["acc"]
                   >= embed_grid[f"nystrom_m{ms[0]}"]["acc"] - 0.02),
               "claim_rls_ge_uniform_nmi": bool(all(
                   r >= u - 0.02 for _, r, u in rls_vs_unif)),
               "claim_frontier_consistent": bool(frontier_says_rls
                                                 and sweep_says_rls),
               "bench": {"n": n, "B": bs, "s": ss, "m": ms,
                         "m_selector": ms_sel, "n_selector": n_sel,
                         "method": "exact+rff+nystrom",
                         "selectors": ["uniform", "kpp", "rls"]}}
    save("fig5_approx_sweep", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
