"""Paper Fig.5: accuracy + execution time over the (B, s) grid on MNIST,
extended with the embedded-space sweep over m (the second approximation knob).

Claims validated (paper §4.2):
  * accuracy decreases slightly as B grows,
  * accuracy decreases almost monotonically with s, dropping hard s < 0.2,
  * execution time falls roughly like s (kernel evaluations ~ s N^2 / B).

Beyond-paper (repro.approx): for method in (rff, nystrom) sweep the
embedding dimension m at fixed B — accuracy rises with m (approaching the
exact kernel fit) while cost scales with n*m instead of s*(N/B)^2. Emitted
under the same JSON schema (one record per grid point with accuracy and
seconds) as the (B, s) grid.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_mnist_like

from .common import Timer, save, table


def run(fast: bool = True):
    n = 4000 if fast else 60000
    n_test = 1000 if fast else 10000
    bs = [1, 2, 4] if fast else [1, 2, 4, 8]
    ss = [0.05, 0.2, 0.5, 1.0] if fast else [0.025, 0.05, 0.1, 0.2, 0.4,
                                             0.7, 1.0]
    x, y = make_mnist_like(n + n_test, seed=0)
    x_tr, y_tr = x[:n], y[:n]
    x_te, y_te = x[n:], y[n:]
    gamma = gamma_from_dmax(jnp.asarray(x_tr[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)

    grid = {}
    rows = []
    for b in bs:
        for s in ss:
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b, s=s,
                                  kernel=spec, seed=0)
            with Timer() as t:
                res = fit_dataset(x_tr, cfg)
            labels = np.asarray(predict(jnp.asarray(x_te), res.state.medoids,
                                        res.state.medoid_diag, spec=spec))
            acc = clustering_accuracy(y_te, labels)
            grid[f"B{b}_s{s}"] = {"B": b, "s": s, "acc": acc,
                                  "seconds": t.seconds}
            rows.append([b, s, f"{acc:.3f}", f"{t.seconds:.2f}s"])

    table("Fig.5 — (B, s) sweep on MNIST-like (test accuracy)",
          ["B", "s", "accuracy", "time"], rows)

    # paper-claim checks
    accs_at_s1 = [grid[f"B{b}_s1.0"]["acc"] for b in bs]
    acc_smin = grid[f"B{bs[0]}_s{ss[0]}"]["acc"]
    acc_s1 = grid[f"B{bs[0]}_s1.0"]["acc"]
    t_smin = grid[f"B{bs[0]}_s{ss[0]}"]["seconds"]
    t_s1 = grid[f"B{bs[0]}_s1.0"]["seconds"]
    print(f"[fig5] acc vs B at s=1: {[f'{a:.3f}' for a in accs_at_s1]} "
          f"(mild decrease expected)")
    print(f"[fig5] s={ss[0]}: acc {acc_smin:.3f} vs s=1 {acc_s1:.3f}; "
          f"time {t_smin:.2f}s vs {t_s1:.2f}s")
    # -- embedded-space sweep: m for rff/nystrom at fixed B ----------------
    b_embed = bs[0]
    ms = [20, 40, 80] if fast else [20, 40, 80, 160, 320]
    embed_grid = {}
    embed_rows = []
    for method in ("rff", "nystrom"):
        for m in ms:
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b_embed,
                                  kernel=spec, seed=0, method=method,
                                  embed_dim=m)
            with Timer() as t:
                res = fit_dataset(x_tr, cfg)
            labels = np.asarray(res.predict(jnp.asarray(x_te)))
            acc = clustering_accuracy(y_te, labels)
            embed_grid[f"{method}_m{m}"] = {"method": method, "B": b_embed,
                                            "m": m, "acc": acc,
                                            "seconds": t.seconds}
            embed_rows.append([method, m, f"{acc:.3f}", f"{t.seconds:.2f}s"])

    table("Fig.5+ — embedding-dim sweep (rff/nystrom, test accuracy)",
          ["method", "m", "accuracy", "time"], embed_rows)

    for method in ("rff", "nystrom"):
        accs = [embed_grid[f"{method}_m{m}"]["acc"] for m in ms]
        print(f"[fig5] {method}: acc over m={ms}: "
              f"{[f'{a:.3f}' for a in accs]} (rise toward exact expected)")

    payload = {"grid": grid,
               "embed_grid": embed_grid,
               "claim_acc_drops_with_B": bool(accs_at_s1[-1]
                                              <= accs_at_s1[0] + 0.02),
               "claim_small_s_cheaper": bool(t_smin < t_s1),
               "claim_acc_rises_with_m": bool(
                   embed_grid[f"nystrom_m{ms[-1]}"]["acc"]
                   >= embed_grid[f"nystrom_m{ms[0]}"]["acc"] - 0.02)}
    save("fig5_approx_sweep", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
