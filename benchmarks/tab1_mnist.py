"""Paper Tab.1: MNIST accuracy/NMI/time for B in {1, 4, 16, 64} + the
linear k-means baseline.

Paper numbers (60k train/10k test, RBF sigma=4 d_max): acc 86.5 -> 78.4 and
NMI 0.74 -> 0.63 as B goes 1 -> 64; time falls ~B x. Claims validated here:
the same monotone trends on the synthetic MNIST envelope, and kernel@B=1
>= linear baseline.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.baselines.lloyd import kmeans
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax, nmi)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_mnist_like

from .common import Timer, nearest_centroid, save, table


def run(fast: bool = True, *, n_seeds: int = 3):
    n = 6000 if fast else 60000
    n_test = 1000 if fast else 10000
    bs = [1, 4, 16] if fast else [1, 4, 16, 64]
    x, y = make_mnist_like(n + n_test, seed=0)
    x_tr, y_tr, x_te, y_te = x[:n], y[:n], x[n:], y[n:]
    gamma = gamma_from_dmax(jnp.asarray(x_tr[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)

    rows, payload = [], {"B": {}}

    with Timer() as t:
        base = kmeans(x_tr, 10, n_init=3, seed=0)
    base_labels = nearest_centroid(x_te, np.asarray(base.centers))
    b_acc = clustering_accuracy(y_te, base_labels)
    b_nmi = nmi(y_te, base_labels)
    rows.append(["baseline (linear)", f"{b_acc*100:.2f}", f"{b_nmi:.3f}",
                 f"{t.seconds:.1f}s"])
    payload["baseline"] = {"acc": b_acc, "nmi": b_nmi, "seconds": t.seconds}

    for b in bs:
        accs, nmis, times = [], [], []
        for seed in range(n_seeds):
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b, s=1.0,
                                  kernel=spec, seed=seed)
            with Timer() as t:
                res = fit_dataset(x_tr, cfg)
            labels = np.asarray(predict(jnp.asarray(x_te),
                                        res.state.medoids,
                                        res.state.medoid_diag, spec=spec))
            accs.append(clustering_accuracy(y_te, labels))
            nmis.append(nmi(y_te, labels))
            times.append(t.seconds)
        rows.append([f"B={b}", f"{np.mean(accs)*100:.2f}±{np.std(accs)*100:.2f}",
                     f"{np.mean(nmis):.3f}±{np.std(nmis):.3f}",
                     f"{np.mean(times):.1f}s"])
        payload["B"][b] = {"acc": float(np.mean(accs)),
                           "acc_std": float(np.std(accs)),
                           "nmi": float(np.mean(nmis)),
                           "seconds": float(np.mean(times))}

    table("Tab.1 — MNIST-like, B sweep", ["run", "accuracy %", "NMI",
                                          "time"], rows)
    accs = [payload["B"][b]["acc"] for b in bs]
    times = [payload["B"][b]["seconds"] for b in bs]
    payload["claim_acc_monotone_decreasing"] = bool(
        accs[-1] <= accs[0] + 0.02)
    payload["claim_time_drops_with_B"] = bool(times[-1] < times[0])
    payload["claim_kernel_beats_linear_at_B1"] = bool(
        accs[0] >= b_acc - 0.02)
    print(f"[tab1] acc(B): {[f'{a:.3f}' for a in accs]}, "
          f"time(B): {[f'{t:.1f}' for t in times]}, "
          f"linear baseline {b_acc:.3f}")
    save("tab1_mnist", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
