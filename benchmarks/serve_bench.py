"""Assignment-serving latency benchmark (ISSUE 10 measurement).

Fits a small RFF model, freezes it (``repro.serving.freeze``), AOT-warms an
``AssignService`` and drives an OPEN-LOOP offered-QPS request stream
through the continuous-batching queue — arrivals are scheduled by the
offered rate, not by service completion, so queueing delay is measured
honestly. The grid covers >= 2 offered QPS levels x >= 2 request sizes
(landing in different shape buckets); each cell reports p50/p99 request
latency (arrival -> labels on host) and sustained rows/sec.

    PYTHONPATH=src python benchmarks/serve_bench.py --fast

writes ``results/BENCH_serve.json`` (the perf-trajectory record — also
produced by ``benchmarks/run.py``, which diffs it against the previous
revision) and the per-request obs JSONL next to it. The analytic price
(``core.memory.serve_footprint_bytes``) is recorded beside the measured
``artifact_nbytes``.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

if __name__ == "__main__" and __package__ is None:   # direct-script escape
    import sys
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import common                                    # noqa: F401
    from common import record_bench, save
else:
    from .common import record_bench, save

OBS_PATH = os.path.join(
    os.environ.get("REPRO_BENCH", "results"), "serve_obs.jsonl")


def _build_service(fast: bool, recorder):
    from repro.core.minibatch import MiniBatchConfig, fit_dataset
    from repro.data.synthetic import make_blobs
    from repro.serving import AssignServeConfig, AssignService, freeze

    n, d, c = (2048, 16, 8) if fast else (20000, 32, 16)
    x, _ = make_blobs(n, d, c, seed=0)
    cfg = MiniBatchConfig(n_clusters=c, n_batches=4, method="rff",
                          embed_dim=8 * c, seed=0)
    art = freeze(fit_dataset(np.asarray(x), cfg))
    t0 = time.perf_counter()
    svc = AssignService(art, AssignServeConfig(), recorder=recorder)
    return art, svc, time.perf_counter() - t0


def _offered_load(svc, xs, qps: float) -> tuple[list[float], float]:
    """Open loop: request i arrives at i/qps regardless of service state.
    Returns (per-request latencies [s], elapsed wall seconds)."""
    arrive = [i / qps for i in range(len(xs))]
    uid2arr, lat = {}, []
    t0 = time.perf_counter()
    submitted = 0
    while len(lat) < len(xs):
        now = time.perf_counter() - t0
        while submitted < len(xs) and arrive[submitted] <= now:
            uid = svc.submit(xs[submitted])
            uid2arr[uid] = arrive[submitted]
            submitted += 1
        if submitted > len(lat):
            for uid in svc.step():
                lat.append((time.perf_counter() - t0) - uid2arr[uid])
        elif submitted < len(xs):
            time.sleep(max(0.0, arrive[submitted]
                           - (time.perf_counter() - t0)))
    return lat, time.perf_counter() - t0


def run(fast: bool = True):
    from repro.core.memory import serve_footprint_bytes
    from repro.obs import JsonlRecorder, export
    from repro.serving import artifact_nbytes

    os.makedirs(os.path.dirname(OBS_PATH) or ".", exist_ok=True)
    rec = JsonlRecorder(OBS_PATH, header=export.run_header(
        entry="benchmarks.serve_bench", fast=fast))
    art, svc, warm_s = _build_service(fast, rec)
    d, c, m = art.in_dim, art.n_clusters, art.dim

    qps_levels = (50.0, 200.0) if fast else (100.0, 500.0)
    row_sizes = (1, 64)                  # land in different shape buckets
    n_req = 40 if fast else 200
    rng = np.random.default_rng(0)

    cells = {}
    for rows in row_sizes:
        xs = [rng.normal(size=(rows, d)).astype(np.float32)
              for _ in range(n_req)]
        for qps in qps_levels:
            lat, elapsed = _offered_load(svc, xs, qps)
            p50, p99 = np.percentile(lat, [50, 99])
            cells[f"qps{qps:g}_rows{rows}"] = {
                "offered_qps": qps, "rows_per_request": rows,
                "requests": n_req,
                "p50_ms": float(p50 * 1e3), "p99_ms": float(p99 * 1e3),
                "rows_per_s": float(rows * n_req / elapsed),
            }
            print(f"[serve] qps={qps:g} rows={rows}: "
                  f"p50 {p50*1e3:.2f}ms p99 {p99*1e3:.2f}ms "
                  f"{rows * n_req / elapsed:.0f} rows/s")
    rec.close()

    bench = {
        "kind": art.kind, "precision": art.precision,
        "buckets": list(svc.cfg.buckets),
        "compiled_programs": svc.compiled_programs,
        "warm_seconds": warm_s,
        "artifact_bytes": artifact_nbytes(art),
        "predicted_bytes": serve_footprint_bytes(
            c, m, d, method=art.kind, bucket=max(svc.cfg.buckets)),
        "cells": cells,
    }
    payload = {"bench": bench, "obs": export.summarize(OBS_PATH),
               "dtype": art.precision}
    save("serve", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    fast = not args.full
    t0 = time.time()
    payload = run(fast=fast)
    record_bench("serve", time.time() - t0, mode="fast" if fast else "full",
                 params=payload["bench"], obs=payload.get("obs"),
                 dtype=payload.get("dtype", "f32"))
    print(f"BENCH_serve.json recorded "
          f"({os.environ.get('REPRO_BENCH', 'results')})")


if __name__ == "__main__":
    main()
