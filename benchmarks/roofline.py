"""Roofline analysis: GramEngine mode sweep + dry-run artifact terms.

Part 1 — engine sweep (always runs): the exact inner loop under each
GramEngine mode (materialize / fused / tiled, repro.core.engine) on one
mini-batch, measuring wall time and reporting the modeled per-iteration
HBM traffic per row each residency implies:

    materialize:  Q * (|L| + C)    bytes/row  (read resident K + write f)
    fused:        Q * (d + C)      bytes/row  (features in, f out; Gram
                                               tiles never leave VMEM —
                                               only when the Pallas path is
                                               live; the jnp fallback is
                                               recorded at panel traffic)
    tiled:        Q * (|L| + C + d) bytes/row (panel streamed through HBM)

Each BENCH record names the ``path`` that actually ran (pallas /
jnp-fallback / resident / streamed-panels) so trajectory diffs never
compare a VMEM model against a fallback measurement.

The three modes must label identically (asserted); the sweep records a
``bench`` dict (mode -> seconds / iters / bytes-per-row / rows-per-sec)
that benchmarks/run.py persists as results/BENCH_roofline.json — the perf
trajectory of the engine subsystem. In fast (CI) mode the fused engine
runs the Pallas kernel in interpret mode, so the kernel path compile-checks
on every push.

Part 2 — dry-run terms (when results/dryrun/*.json artifacts exist):

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

    compute term    = HLO_FLOPs / (chips x 197e12)          [s]
    memory term     = HLO_bytes / (chips x 819e9)           [s]
    collective term = coll_link_bytes / (chips x 50e9)      [s]

HLO_FLOPs / bytes are the LOOP-AWARE per-device numbers (repro.launch.
hlocost multiplies while-loop trip counts through the call graph;
``cost_analysis()`` visits each body once and under-reports scans by
~n_layers x). Collective bytes use ring costs per op. The dominant term is
the bottleneck the §Perf loop iterates on; MODEL_FLOPS / HLO_FLOPs shows
how much compiled compute is "useful" (remat recompute and padding waste
push it below 1).
"""
from __future__ import annotations

import glob
import json
import os

from .common import save, table

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-device link budget)


def load_cells(dryrun_dir: str = "results/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            continue
        la = d.get("loop_aware")
        if not la:
            continue
        cells.append(d)
    return cells


def terms(cell: dict) -> dict:
    la = cell["loop_aware"]
    t_comp = la["flops_per_device"] / PEAK_FLOPS
    t_mem = la["bytes_per_device"] / HBM_BW
    t_coll = la["collective_bytes"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    chips = 512 if cell["mesh"] == "2x16x16" else 256
    model_per_dev = cell["model_flops_total"] / chips
    useful = model_per_dev / max(la["flops_per_device"], 1.0)
    # roofline fraction: achievable step time is bounded below by every
    # term; fraction = compute term / max(all terms)
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant[0], "bound_s": bound,
        "roofline_fraction": frac,
        "model_flops_ratio": useful,
        "n_params": cell["n_params"],
        "n_active_params": cell.get("n_active_params", cell["n_params"]),
    }


def engine_sweep(fast: bool = True) -> dict:
    """Measure the three GramEngine modes on one exact mini-batch."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import GramEngine, KernelSpec
    from repro.core.kkmeans import kkmeans_fit

    n, d, c = (512, 32, 8) if fast else (8192, 128, 32)
    s = 0.25
    lm = int(n * s)
    tile_rows = 128
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    spec = KernelSpec("rbf", gamma=1.0 / d)
    diag = spec.diag(x)
    l_idx = jnp.asarray(np.sort(rng.choice(n, lm, replace=False)), jnp.int32)
    u0 = jnp.asarray(rng.integers(0, c, n), jnp.int32)

    engines = {
        "materialize": GramEngine("materialize"),
        # fast/CI: interpret mode exercises the Pallas kernel body on CPU
        # (the compile-check); full mode lets dispatch pick the backend.
        "fused": GramEngine("fused", pallas="always" if fast else "auto",
                            interpret=fast),
        "tiled": GramEngine("tiled", tile_rows=tile_rows),
    }
    # the bytes model must describe the path that ACTUALLY runs: off-TPU
    # without interpret mode, the fused engine's portable fallback
    # transiently materializes the block — recording the VMEM-residency
    # figure for it would poison the BENCH baseline.
    fused_pallas = engines["fused"]._use_pallas(spec)
    bytes_per_row = {
        "materialize": 4.0 * (lm + c),
        "fused": 4.0 * (d + c) if fused_pallas else 4.0 * (lm + c + d),
        "tiled": 4.0 * (lm + c + d),
    }
    paths = {
        "materialize": "resident",
        "fused": "pallas" + ("-interpret" if fast else "")
                 if fused_pallas else "jnp-fallback",
        "tiled": "streamed-panels",
    }
    bench = {"n": n, "d": d, "C": c, "L": lm, "tile_rows": tile_rows,
             "modes": {}}
    rows, labels_by_mode = [], {}
    for mode, eng in engines.items():
        fit = lambda: kkmeans_fit(x, l_idx, diag, u0, spec=spec,  # noqa: E731
                                  n_clusters=c, engine=eng)
        res = fit()                          # compile + warm cache
        jax.block_until_ready(res.labels)
        t0 = time.time()
        res = fit()
        jax.block_until_ready(res.labels)
        dt = time.time() - t0
        iters = int(res.n_iter)
        rows_per_s = n * max(iters, 1) / max(dt, 1e-9)
        labels_by_mode[mode] = np.asarray(res.labels)
        bench["modes"][mode] = {
            "seconds": dt, "iters": iters,
            "path": paths[mode],
            "bytes_per_row_iter": bytes_per_row[mode],
            "rows_per_sec": rows_per_s,
            "achieved_bytes_per_sec": bytes_per_row[mode] * rows_per_s,
        }
        rows.append([mode, paths[mode], f"{dt*1e3:.1f}", iters,
                     f"{bytes_per_row[mode]:.0f}",
                     f"{rows_per_s/1e3:.1f}k"])
    base = labels_by_mode["materialize"]
    for mode, lab in labels_by_mode.items():
        assert (lab == base).all(), \
            f"engine mode {mode} diverged from materialize labels"
    table(f"GramEngine mode sweep (n={n}, |L|={lm}, C={c}, d={d})",
          ["mode", "path", "wall ms", "iters", "bytes/row/iter", "rows/s"],
          rows)
    return bench


def run(fast: bool = True, dryrun_dir: str = "results/dryrun",
        mesh: str = "16x16"):
    bench = engine_sweep(fast=fast)
    cells = [c for c in load_cells(dryrun_dir) if c["mesh"] == mesh]
    if not cells:
        print(f"[roofline] no dry-run artifacts in {dryrun_dir} — run "
              f"`python -m repro.launch.dryrun --all` for the per-arch "
              f"roofline terms (engine sweep above ran regardless)")
        save("roofline", {"engine_sweep": bench})
        return {"engine_sweep": bench, "bench": bench}
    rows, payload = [], {}
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for c in sorted(cells,
                    key=lambda c: (c["arch"], order.get(c["shape"], 9))):
        t = terms(c)
        payload[f"{t['arch']}|{t['shape']}"] = t
        rows.append([
            t["arch"], t["shape"],
            f"{t['t_compute_s']*1e3:.2f}", f"{t['t_memory_s']*1e3:.2f}",
            f"{t['t_collective_s']*1e3:.2f}", t["dominant"],
            f"{t['roofline_fraction']:.2f}",
            f"{t['model_flops_ratio']:.2f}"])
    table(f"Roofline terms per cell (mesh {mesh}; ms/step)",
          ["arch", "shape", "t_comp", "t_mem", "t_coll", "dominant",
           "roofline frac", "useful-FLOPs"], rows)
    # the three hillclimb picks (worst frac / most collective-bound /
    # most paper-representative) are documented in EXPERIMENTS.md §Perf.
    payload["engine_sweep"] = bench
    save("roofline", payload)
    payload["bench"] = bench
    return payload


if __name__ == "__main__":
    run(fast=False)
