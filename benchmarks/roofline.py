"""Roofline analysis over the dry-run artifacts (assignment deliverable g).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

    compute term    = HLO_FLOPs / (chips x 197e12)          [s]
    memory term     = HLO_bytes / (chips x 819e9)           [s]
    collective term = coll_link_bytes / (chips x 50e9)      [s]

HLO_FLOPs / bytes are the LOOP-AWARE per-device numbers (repro.launch.
hlocost multiplies while-loop trip counts through the call graph;
``cost_analysis()`` visits each body once and under-reports scans by
~n_layers x). Collective bytes use ring costs per op. The dominant term is
the bottleneck the §Perf loop iterates on; MODEL_FLOPS / HLO_FLOPs shows
how much compiled compute is "useful" (remat recompute and padding waste
push it below 1).
"""
from __future__ import annotations

import glob
import json
import os

from .common import save, table

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-device link budget)


def load_cells(dryrun_dir: str = "results/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            continue
        la = d.get("loop_aware")
        if not la:
            continue
        cells.append(d)
    return cells


def terms(cell: dict) -> dict:
    la = cell["loop_aware"]
    t_comp = la["flops_per_device"] / PEAK_FLOPS
    t_mem = la["bytes_per_device"] / HBM_BW
    t_coll = la["collective_bytes"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    chips = 512 if cell["mesh"] == "2x16x16" else 256
    model_per_dev = cell["model_flops_total"] / chips
    useful = model_per_dev / max(la["flops_per_device"], 1.0)
    # roofline fraction: achievable step time is bounded below by every
    # term; fraction = compute term / max(all terms)
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant[0], "bound_s": bound,
        "roofline_fraction": frac,
        "model_flops_ratio": useful,
        "n_params": cell["n_params"],
        "n_active_params": cell.get("n_active_params", cell["n_params"]),
    }


def run(fast: bool = True, dryrun_dir: str = "results/dryrun",
        mesh: str = "16x16"):
    cells = [c for c in load_cells(dryrun_dir) if c["mesh"] == mesh]
    if not cells:
        print(f"[roofline] no dry-run artifacts in {dryrun_dir} — run "
              f"`python -m repro.launch.dryrun --all` first")
        return {}
    rows, payload = [], {}
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for c in sorted(cells,
                    key=lambda c: (c["arch"], order.get(c["shape"], 9))):
        t = terms(c)
        payload[f"{t['arch']}|{t['shape']}"] = t
        rows.append([
            t["arch"], t["shape"],
            f"{t['t_compute_s']*1e3:.2f}", f"{t['t_memory_s']*1e3:.2f}",
            f"{t['t_collective_s']*1e3:.2f}", t["dominant"],
            f"{t['roofline_fraction']:.2f}",
            f"{t['model_flops_ratio']:.2f}"])
    table(f"Roofline terms per cell (mesh {mesh}; ms/step)",
          ["arch", "shape", "t_comp", "t_mem", "t_coll", "dominant",
           "roofline frac", "useful-FLOPs"], rows)
    # the three hillclimb picks (worst frac / most collective-bound /
    # most paper-representative) are documented in EXPERIMENTS.md §Perf.
    save("roofline", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
