"""Roofline analysis: GramEngine mode x dtype sweep + dry-run terms.

Part 1 — engine sweep (always runs): the exact inner loop under each
GramEngine mode (materialize / fused / tiled, repro.core.engine) AND each
tile precision ("f32" / "bf16", repro.kernels.precision) on one
mini-batch, measuring wall time and reporting the modeled per-iteration
HBM traffic per row each (residency, dtype) implies — Q_t is the tile
itemsize (4 or 2), the f panel is always 4-byte f32:

    materialize:  Q_t*|L| + 4*C     bytes/row  (read resident K + write f)
    fused:        Q_t*d + 4*C       bytes/row  (features in, f out; Gram
                                                tiles never leave VMEM —
                                                only when the Pallas path
                                                is live; the jnp fallback
                                                is recorded at panel
                                                traffic)
    tiled:        Q_t*(|L|+d) + 4*C bytes/row  (panel streamed through HBM)

Each BENCH record names the ``path`` that actually ran (pallas /
jnp-fallback / resident / streamed-panels) plus its ``dtype`` and
``backend`` columns, so trajectory diffs never compare a VMEM model
against a fallback measurement or a bf16 run against an f32 baseline.

The fixture is well-separated blobs, so every (mode, dtype) cell must
label IDENTICALLY (asserted — the bf16 tile rounding is absorbed by the
cluster margins; tests/test_precision.py pins the same invariant). The
bf16 fused cell must not be slower than the f32 fused cell: strictly on a
real accelerator (halved HBM traffic), within a noise tolerance on the
CPU interpret path, where the kernel body is *emulated* and there is no
memory-bandwidth term for the dtype to win — the comparison there is a
guard against pathological cast overhead, not a speedup claim.

The sweep records a ``bench`` dict ("mode|dtype" -> seconds / iters /
bytes-per-row / rows-per-sec / dtype / backend) that benchmarks/run.py
persists as results/BENCH_roofline.json — the perf trajectory of the
engine subsystem. In fast (CI) mode the fused engine runs the Pallas
kernel in interpret mode, so the kernel path compile-checks both dtypes
on every push.

Part 2 — dry-run terms (when results/dryrun/*.json artifacts exist):

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

    compute term    = HLO_FLOPs / (chips x 197e12)          [s]
    memory term     = HLO_bytes / (chips x 819e9)           [s]
    collective term = coll_link_bytes / (chips x 50e9)      [s]

HLO_FLOPs / bytes are the LOOP-AWARE per-device numbers (repro.launch.
hlocost multiplies while-loop trip counts through the call graph;
``cost_analysis()`` visits each body once and under-reports scans by
~n_layers x). Collective bytes use ring costs per op. The dominant term is
the bottleneck the §Perf loop iterates on; MODEL_FLOPS / HLO_FLOPs shows
how much compiled compute is "useful" (remat recompute and padding waste
push it below 1).
"""
from __future__ import annotations

import glob
import json
import os

from .common import save, table

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-device link budget)


def load_cells(dryrun_dir: str = "results/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(path))
        if not d.get("ok"):
            continue
        la = d.get("loop_aware")
        if not la:
            continue
        cells.append(d)
    return cells


def terms(cell: dict) -> dict:
    la = cell["loop_aware"]
    t_comp = la["flops_per_device"] / PEAK_FLOPS
    t_mem = la["bytes_per_device"] / HBM_BW
    t_coll = la["collective_bytes"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])
    chips = 512 if cell["mesh"] == "2x16x16" else 256
    model_per_dev = cell["model_flops_total"] / chips
    useful = model_per_dev / max(la["flops_per_device"], 1.0)
    # roofline fraction: achievable step time is bounded below by every
    # term; fraction = compute term / max(all terms)
    bound = max(t_comp, t_mem, t_coll)
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant[0], "bound_s": bound,
        "roofline_fraction": frac,
        "model_flops_ratio": useful,
        "n_params": cell["n_params"],
        "n_active_params": cell.get("n_active_params", cell["n_params"]),
    }


def engine_sweep(fast: bool = True) -> dict:
    """Measure the GramEngine modes x tile dtypes on one exact mini-batch."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from sklearn.datasets import make_blobs

    from repro.core import GramEngine, KernelSpec
    from repro.core.kkmeans import kkmeans_fit
    from repro.kernels import PRECISIONS, resolve_precision

    n, d, c = (512, 32, 8) if fast else (8192, 128, 32)
    s = 0.25
    lm = int(n * s)
    tile_rows = 128
    # well-separated blobs: the cross-dtype labels-identical assert below
    # needs cluster margins that absorb the bf16 tile rounding.
    xs, _ = make_blobs(n_samples=n, n_features=d, centers=c,
                       cluster_std=0.4, center_box=(-8.0, 8.0),
                       random_state=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(xs.astype(np.float32))
    spec = KernelSpec("rbf", gamma=1.0 / d)
    diag = spec.diag(x)
    l_idx = jnp.asarray(np.sort(rng.choice(n, lm, replace=False)), jnp.int32)
    u0 = jnp.asarray(rng.integers(0, c, n), jnp.int32)
    # the comparability column is the PLATFORM the sweep ran on (a cpu
    # interpret-mode figure must never baseline a tpu run); the kernel-body
    # flavor (Mosaic/Triton) is implied by it — kernels/backend.py.
    backend = jax.default_backend()
    on_accelerator = backend in ("tpu", "gpu")
    repeats = 3 if fast else 5

    bench = {"n": n, "d": d, "C": c, "L": lm, "tile_rows": tile_rows,
             "backend": backend, "modes": {}}
    rows, labels_by_cell, seconds_by_cell = [], {}, {}
    for precision in PRECISIONS:
        qt = resolve_precision(precision).tile_itemsize
        engines = {
            "materialize": GramEngine("materialize", precision=precision),
            # fast/CI: interpret mode exercises the Pallas kernel body on
            # CPU (the compile-check, both dtypes); full mode lets dispatch
            # pick the backend.
            "fused": GramEngine("fused",
                                pallas="always" if fast else "auto",
                                interpret=fast, precision=precision),
            "tiled": GramEngine("tiled", tile_rows=tile_rows,
                                precision=precision),
        }
        # the bytes model must describe the path that ACTUALLY runs:
        # off-TPU without interpret mode, the fused engine's portable
        # fallback transiently materializes the block — recording the
        # VMEM-residency figure for it would poison the BENCH baseline.
        fused_pallas = engines["fused"]._use_pallas(spec)
        bytes_per_row = {
            "materialize": qt * lm + 4.0 * c,
            "fused": (qt * d + 4.0 * c) if fused_pallas
                     else qt * (lm + d) + 4.0 * c,
            "tiled": qt * (lm + d) + 4.0 * c,
        }
        paths = {
            "materialize": "resident",
            "fused": "pallas" + ("-interpret" if fast else "")
                     if fused_pallas else "jnp-fallback",
            "tiled": "streamed-panels",
        }
        for mode, eng in engines.items():
            fit = lambda: kkmeans_fit(x, l_idx, diag, u0,  # noqa: E731
                                      spec=spec, n_clusters=c, engine=eng)
            res = fit()                          # compile + warm cache
            jax.block_until_ready(res.labels)
            dt = float("inf")
            for _ in range(repeats):
                t0 = time.time()
                res = fit()
                jax.block_until_ready(res.labels)
                dt = min(dt, time.time() - t0)
            iters = int(res.n_iter)
            rows_per_s = n * max(iters, 1) / max(dt, 1e-9)
            cell = f"{mode}|{precision}"
            labels_by_cell[cell] = np.asarray(res.labels)
            seconds_by_cell[cell] = dt
            bench["modes"][cell] = {
                "mode": mode, "dtype": precision, "backend": backend,
                "seconds": dt, "iters": iters,
                "path": paths[mode],
                "bytes_per_row_iter": bytes_per_row[mode],
                "rows_per_sec": rows_per_s,
                "achieved_bytes_per_sec": bytes_per_row[mode] * rows_per_s,
            }
            rows.append([mode, precision, backend, paths[mode],
                         f"{dt*1e3:.1f}", iters,
                         f"{bytes_per_row[mode]:.0f}",
                         f"{rows_per_s/1e3:.1f}k"])
    base = labels_by_cell["materialize|f32"]
    for cell, lab in labels_by_cell.items():
        assert (lab == base).all(), \
            f"engine cell {cell} diverged from materialize|f32 labels"
    # the tentpole's wall-clock claim: bf16 tiles must not cost time. On a
    # real accelerator the fused path's HBM term halves, so strict <=; the
    # CPU interpret path emulates the kernel body (no bandwidth term) and
    # only guards against pathological cast overhead, within timer noise.
    f32_fused, bf16_fused = seconds_by_cell["fused|f32"], \
        seconds_by_cell["fused|bf16"]
    tol = 1.0 if on_accelerator else 1.25
    assert bf16_fused <= tol * f32_fused, (
        f"bf16 fused {bf16_fused*1e3:.1f} ms > {tol:g} x f32 fused "
        f"{f32_fused*1e3:.1f} ms")
    bench["bf16_fused_speedup"] = f32_fused / max(bf16_fused, 1e-9)
    table(f"GramEngine mode x dtype sweep (n={n}, |L|={lm}, C={c}, d={d}, "
          f"backend={backend})",
          ["mode", "dtype", "backend", "path", "wall ms", "iters",
           "bytes/row/iter", "rows/s"], rows)
    return bench


def run(fast: bool = True, dryrun_dir: str = "results/dryrun",
        mesh: str = "16x16"):
    bench = engine_sweep(fast=fast)
    cells = [c for c in load_cells(dryrun_dir) if c["mesh"] == mesh]
    if not cells:
        print(f"[roofline] no dry-run artifacts in {dryrun_dir} — run "
              f"`python -m repro.launch.dryrun --all` for the per-arch "
              f"roofline terms (engine sweep above ran regardless)")
        save("roofline", {"engine_sweep": bench})
        return {"engine_sweep": bench, "bench": bench}
    rows, payload = [], {}
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for c in sorted(cells,
                    key=lambda c: (c["arch"], order.get(c["shape"], 9))):
        t = terms(c)
        payload[f"{t['arch']}|{t['shape']}"] = t
        rows.append([
            t["arch"], t["shape"],
            f"{t['t_compute_s']*1e3:.2f}", f"{t['t_memory_s']*1e3:.2f}",
            f"{t['t_collective_s']*1e3:.2f}", t["dominant"],
            f"{t['roofline_fraction']:.2f}",
            f"{t['model_flops_ratio']:.2f}"])
    table(f"Roofline terms per cell (mesh {mesh}; ms/step)",
          ["arch", "shape", "t_comp", "t_mem", "t_coll", "dominant",
           "roofline frac", "useful-FLOPs"], rows)
    # the three hillclimb picks (worst frac / most collective-bound /
    # most paper-representative) are documented in EXPERIMENTS.md §Perf.
    payload["engine_sweep"] = bench
    save("roofline", payload)
    payload["bench"] = bench
    return payload


if __name__ == "__main__":
    run(fast=False)
