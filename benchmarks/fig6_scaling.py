"""Paper Fig.6: strong scaling of the distributed inner loop.

Three measurements, honestly separated:

  1. MEASURED wall-time on forced host devices P in {1, 2, 4, 8}. On one
     physical CPU these shards share cores, so perfect scaling is NOT
     expected — what must hold (and is asserted) is that the collective
     STRUCTURE stays the paper's (2 collectives/iter, constant byte volume
     per device count) and per-device work falls as 1/P.
  2. ANALYTIC model from the dry-run numbers on the production mesh
     (compute t ~ N^2/(B^2 P), comms t ~ the all-gather(U)+all-reduce(g)
     ring costs) — the BG/Q-style near-linear regime the paper reports.

  3. SPARSE streaming column (Fig.6c): the sharded-CSR ingestion path —
     per-device O(nnz) count-sketch embed + one psum of C*(m+1) floats per
     Lloyd sweep (repro.distributed.embed) on a high-dimensional sparse
     corpus that is never densified. Same caveat as (1): forced host
     devices share one CPU, so the honest claim is per-device work falling
     as 1/P at constant collective volume, not wall-clock speedup.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import save, table

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_script(script: str, timeout: int = 560) -> dict:
    """Run a measurement script in a clean subprocess (forced device count
    must be set before jax import); it must print one JSON line last."""
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _measure(p: int, n: int, d: int, c: int, s_step: int = 1) -> dict:
    script = textwrap.dedent(f"""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={p}"
        import json, time
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import KernelSpec, nmi
        from repro.data.synthetic import make_blobs
        from repro.distributed.compat import make_mesh
        from repro.distributed.inner import (DistributedInnerConfig,
                                             collectives_per_iteration,
                                             distributed_kkmeans_fit)

        rng = np.random.default_rng(0)
        xh, y = make_blobs({n}, {d}, {c}, sep=4.0, seed=0)
        x = jnp.asarray(xh)
        spec = KernelSpec("rbf", gamma=0.05)
        diag = spec.diag(x)
        l_idx = jnp.arange({n}, dtype=jnp.int32)
        u0 = jnp.asarray(rng.integers(0, {c}, {n}), jnp.int32)
        mesh = make_mesh(({p},), ("data",))
        cfg = DistributedInnerConfig(n_clusters={c}, kernel=spec,
                                     row_axes=("data",), col_axis=None,
                                     max_iters=50, s_step={s_step})
        # compile
        r = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)
        jax.block_until_ready(r.labels)
        t0 = time.time()
        r = distributed_kkmeans_fit(mesh, x, x, l_idx, diag, u0, cfg=cfg)
        jax.block_until_ready(r.labels)
        dt = time.time() - t0
        # per-SYNC bill (repro.distributed.inner): n_iter counts loop
        # bodies = global syncs, +1 for the prologue sync.
        bill = collectives_per_iteration(cfg, {n} // {p})
        syncs = int(r.n_iter) + 1
        print(json.dumps({{"p": {p}, "s_step": {s_step}, "seconds": dt,
                           "iters": int(r.n_iter), "syncs": syncs,
                           "nmi": float(nmi(y, np.asarray(r.labels))),
                           "per_sync": bill,
                           "collectives_total":
                               syncs * (bill["allgather"] + bill["psum"]),
                           "collective_bytes_total":
                               syncs * (4 * {n} // {p} * ({p} - 1)
                                        + bill["psum_bytes"])}}))
    """)
    return _run_script(script)


def _measure_sparse(p: int, n: int, vocab: int, c: int, b: int) -> dict:
    script = textwrap.dedent(f"""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={p}"
        import json, time
        import numpy as np
        import jax
        from repro.core import KernelSpec, MiniBatchConfig
        from repro.data.sparse import split_csr
        from repro.data.synthetic import make_rcv1_sparse
        from repro.distributed.compat import make_mesh
        from repro.distributed.embed import DistributedEmbedKMeans

        xs, _ = make_rcv1_sparse({n}, vocab={vocab}, n_classes={c}, seed=0)
        batches = split_csr(xs, {b}, strategy="stride")
        cfg = MiniBatchConfig(n_clusters={c}, n_batches={b},
                              kernel=KernelSpec("linear"), seed=0,
                              method="sketch", embed_dim=128)
        mesh = make_mesh(({p},), ("data",))
        km = DistributedEmbedKMeans(mesh, cfg)
        km.fit(batches)                       # compile
        t0 = time.time()
        with km.source(batches, depth=2) as src:
            res = km.fit(src)
        dt = time.time() - t0
        print(json.dumps({{"p": {p}, "seconds": dt, "nnz": int(xs.nnz),
                           "batches": int(res.state.batches_done)}}))
    """)
    return _run_script(script)


def analytic_model(n: int, c: int, ps: list[int], *,
                   flops_per_elem: float = 4.0,
                   core_gflops: float = 12.8, net_gbps: float = 10.0):
    """BG/Q-style per-iteration time model: compute N^2/P kernel-row work +
    all-gather(U: N ints) + all-reduce(g: C floats) ring costs."""
    rows = []
    for p in ps:
        t_comp = (n * n / p) * flops_per_elem / (core_gflops * 1e9)
        ag = 4.0 * n * (p - 1) / max(p, 1) / (net_gbps * 1e9)
        ar = 2.0 * 4.0 * c * (p - 1) / max(p, 1) / (net_gbps * 1e9)
        rows.append({"p": p, "seconds": t_comp + ag + ar,
                     "t_comp": t_comp, "t_coll": ag + ar})
    return rows


def run(fast: bool = True):
    import time as _time

    n = 2048 if fast else 16384
    n_sp, vocab = (4096, 4096) if fast else (32768, 47236)
    ps = [1, 2, 4, 8]
    t_bench = _time.time()
    measured = [_measure(p, n, 32, 8) for p in ps]
    # communication-avoiding s-step sweep at fixed P: same fit, s local
    # refinements per global sync — the collective count must fall ~1/s
    # at matched NMI; wall-clock follows where collectives are the
    # bottleneck (forced host devices share one CPU, so the honest CI
    # claim is the collective-count reduction, same caveat as Fig.6a).
    sstep = [_measure(4, n, 32, 8, s_step=s) for s in (1, 2, 4)]
    sparse = [_measure_sparse(p, n_sp, vocab, 8, 4) for p in ps]
    model = analytic_model(65536, 10, [16, 64, 256, 1024])

    rows = [[m["p"], f"{m['seconds']*1e3:.0f}ms",
             f"{measured[0]['seconds']/m['seconds']:.2f}x"]
            for m in measured]
    table(f"Fig.6a — measured strong scaling (1 physical CPU, N={n})",
          ["P (forced devices)", "per-fit wall", "speedup"], rows)

    rows_ss = [[m["s_step"], f"{m['seconds']*1e3:.0f}ms", m["syncs"],
                m["collectives_total"],
                f"{m['collective_bytes_total']/1e3:.1f}kB",
                f"{m['nmi']:.4f}"]
               for m in sstep]
    table(f"Fig.6d — s-step communication avoidance (P=4, N={n}): "
          f"(1 allgather + 1 fused psum)/sync, syncs fall ~1/s",
          ["s", "per-fit wall", "syncs", "collectives", "coll bytes",
           "NMI"], rows_ss)

    rows_sp = [[m["p"], f"{m['seconds']*1e3:.0f}ms",
                f"{sparse[0]['seconds']/m['seconds']:.2f}x",
                f"{m['nnz']//m['p']}"]
               for m in sparse]
    table(f"Fig.6c — sparse streaming sharded-CSR scaling "
          f"(N={n_sp}, d={vocab}, never densified)",
          ["P (forced devices)", "per-fit wall", "speedup", "nnz/device"],
          rows_sp)

    rows2 = [[m["p"], f"{m['seconds']*1e3:.2f}ms",
              f"{model[0]['seconds']*model[0]['p']/m['seconds']/m['p']:.3f}",
              f"{m['t_coll']/m['seconds']*100:.2f}%"]
             for m in model]
    table("Fig.6b — analytic per-iteration model @ production scale "
          "(N=65536, C=10)",
          ["P", "t_iter", "parallel efficiency", "comms share"], rows2)

    payload = {"measured": measured, "s_step": sstep, "sparse": sparse,
               "model": model,
               # workload knobs + the s-step evidence, folded into
               # results/BENCH_fig6_scaling.json by benchmarks.run via
               # common.record_bench: wall/syncs/collective-bytes/NMI per
               # s so a perf regression in the communication-avoiding
               # path is diffable across commits.
               "bench": {"n": n, "ps": ps, "s_steps": [1, 2, 4],
                         "s_step_sweep": [
                             {k: m[k] for k in ("s_step", "seconds",
                                                "syncs",
                                                "collectives_total",
                                                "collective_bytes_total",
                                                "nmi")}
                             for m in sstep],
                         "per_sync_bill": sstep[0]["per_sync"],
                         "setup_seconds": _time.time() - t_bench}}
    save("fig6_scaling", payload)
    eff = model[-1]["seconds"] * model[-1]["p"] / (
        model[0]["seconds"] * model[0]["p"])
    print(f"[fig6] analytic parallel efficiency at P=1024: {1/eff:.3f} "
          f"(paper: near-perfect 16->1024 on BG/Q)")
    return payload


if __name__ == "__main__":
    run(fast=False)
