"""Benchmark driver: one benchmark per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run            # fast (CI) sizes
    PYTHONPATH=src python -m benchmarks.run --full     # paper sizes
    PYTHONPATH=src python -m benchmarks.run --only tab1_mnist
"""
from __future__ import annotations

import argparse
import time
import traceback

from . import (common, fig4_toy, fig5_approx_sweep, fig6_scaling,
               fig8_sculley, roofline, serve_bench, tab1_mnist, tab2_rcv1,
               tab3_noisy)

ALL = {
    "fig4_toy": fig4_toy.run,
    "fig5_approx_sweep": fig5_approx_sweep.run,
    "tab1_mnist": tab1_mnist.run,
    "tab2_rcv1": tab2_rcv1.run,
    "tab3_noisy": tab3_noisy.run,
    "fig6_scaling": fig6_scaling.run,
    "fig8_sculley": fig8_sculley.run,
    "roofline": roofline.run,
    "serve": serve_bench.run,
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (hours); default is fast mode")
    ap.add_argument("--fast", action="store_true",
                    help="CI-size runs (the default; explicit flag for "
                         "smoke-test invocations)")
    ap.add_argument("--only", default=None, choices=sorted(ALL))
    args = ap.parse_args(argv)
    if args.full and args.fast:
        ap.error("--full and --fast are mutually exclusive")

    todo = {args.only: ALL[args.only]} if args.only else ALL
    failures = []
    deltas = []
    run_mode = "full" if args.full else "fast"
    for name, fn in todo.items():
        print(f"\n########## {name} {'(full)' if args.full else '(fast)'} "
              f"##########")
        baseline = common.load_bench(name)
        t0 = time.time()
        try:
            payload = fn(fast=not args.full)
            seconds = time.time() - t0
            print(f"[{name}] finished in {seconds:.1f}s")
            # perf trajectory: diff against the recorded baseline, then
            # re-record one BENCH_<name>.json (wall time, workload knobs
            # from the payload's "bench" dict, commit) so the NEXT revision
            # has this run to compare against. A missing or incomparable
            # baseline still gets a trajectory row — the first run of a
            # fresh checkout (or a wiped results/) must not silently drop
            # out of the summary table.
            run_dtype = (payload or {}).get("dtype", "f32")
            run_backend = common.default_backend()
            mismatch = None
            if baseline:
                for col, want in (("mode", run_mode),
                                  ("dtype", run_dtype),
                                  ("backend", run_backend)):
                    have = baseline.get(col, want)
                    if have != want:
                        mismatch = f"{col}={have!r} vs this run's {want!r}"
                        break
            if baseline and baseline.get("seconds") and not mismatch:
                pct = 100.0 * (seconds - baseline["seconds"]) \
                    / baseline["seconds"]
                print(f"[{name}] baseline {baseline['seconds']:.1f}s "
                      f"@ {baseline.get('commit', '?')} -> "
                      f"{seconds:.1f}s ({pct:+.1f}%)")
                deltas.append((name, baseline["seconds"], seconds, pct))
            else:
                if baseline and mismatch:
                    print(f"[{name}] baseline {mismatch} — not "
                          f"comparable, recording fresh")
                elif not baseline:
                    print(f"[{name}] no recorded baseline — recording "
                          f"this run as the new one")
                deltas.append((name, None, seconds, None))
            common.record_bench(
                name, seconds, mode=run_mode,
                dtype=run_dtype, backend=run_backend,
                params=(payload or {}).get("bench", {}),
                obs=(payload or {}).get("obs"))
        except Exception as e:
            failures.append(name)
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if deltas:
        common.table(
            "Perf trajectory vs recorded baselines",
            ["benchmark", "baseline s", "now s", "delta"],
            [[n, "(new)" if b is None else f"{b:.1f}", f"{s:.1f}",
              "—" if p is None else f"{p:+.1f}%"]
             for n, b, s, p in deltas])
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks green; results under results/benchmarks/ "
          "(+ BENCH_*.json perf records under results/)")


if __name__ == "__main__":
    main()
