"""Paper Fig.8: proposed algorithm vs Sculley's SGD mini-batch k-means,
clustering accuracy vs B on MNIST (sigma = 4 d_max, i.e. near-linear RBF).

Claims validated:
  * proposed accuracy DEGRADES gracefully as B grows, best at small B,
  * Sculley's accuracy is roughly FLAT in B (it never converges per batch),
  * proposed has LOWER variance across seeds than the SGD procedure.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.baselines.sculley import sgd_minibatch_kmeans
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax)
from repro.core.minibatch import fit_dataset, predict
from repro.data.synthetic import make_mnist_like

from .common import save, table


def run(fast: bool = True, n_seeds: int = 3):
    n = 5000 if fast else 60000
    bs = [1, 4, 16] if fast else [1, 4, 16, 64]
    x, y = make_mnist_like(n, seed=0)
    gamma = gamma_from_dmax(jnp.asarray(x[:4096]))
    spec = KernelSpec("rbf", gamma=gamma)

    rows, payload = [], {"ours": {}, "sculley": {}}
    for b in bs:
        ours, sgd = [], []
        for seed in range(n_seeds):
            cfg = MiniBatchConfig(n_clusters=10, n_batches=b, s=1.0,
                                  kernel=spec, seed=seed)
            res = fit_dataset(x, cfg)
            lab = np.asarray(predict(jnp.asarray(x), res.state.medoids,
                                     res.state.medoid_diag, spec=spec))
            ours.append(clustering_accuracy(y, lab))
            # Sculley: same data budget — batch size N/B, B iterations
            # consumes the dataset once (matching our single pass).
            r = sgd_minibatch_kmeans(x, 10, batch_size=max(n // b, 100),
                                     n_iters=max(b, 10), seed=seed)
            sgd.append(clustering_accuracy(y, np.asarray(r.labels)))
        payload["ours"][b] = {"mean": float(np.mean(ours)),
                              "std": float(np.std(ours))}
        payload["sculley"][b] = {"mean": float(np.mean(sgd)),
                                 "std": float(np.std(sgd))}
        rows.append([b,
                     f"{np.mean(ours)*100:.2f}±{np.std(ours)*100:.2f}",
                     f"{np.mean(sgd)*100:.2f}±{np.std(sgd)*100:.2f}"])

    table("Fig.8 — proposed vs Sculley SGD k-means (accuracy % vs B)",
          ["B", "proposed", "Sculley SGD"], rows)
    our_var = np.mean([payload["ours"][b]["std"] for b in bs])
    sgd_var = np.mean([payload["sculley"][b]["std"] for b in bs])
    payload["claim_lower_variance"] = bool(our_var <= sgd_var + 1e-9)
    payload["claim_best_at_small_B"] = bool(
        payload["ours"][bs[0]]["mean"]
        >= payload["ours"][bs[-1]]["mean"] - 0.02)
    print(f"[fig8] mean std ours {our_var:.4f} vs sculley {sgd_var:.4f} "
          f"-> lower-variance claim "
          f"{'CONFIRMED' if payload['claim_lower_variance'] else 'REFUTED'}")
    save("fig8_sculley", payload)
    return payload


if __name__ == "__main__":
    run(fast=False)
