"""End-to-end driver — the paper's §4.5 MD scenario on a synthetic
trajectory (the paper's kind is CLUSTERING, so this is the framework's
end-to-end production example).

    PYTHONPATH=src python examples/cluster_md_trajectory.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/cluster_md_trajectory.py --mesh 4x2

Pipeline (everything the paper describes, wired together):
  frames -> memory-planned (B_min, s) -> stride sampling -> distributed
  mini-batch kernel k-means (5 k-means++ restarts, keep min cost) ->
  medoid extraction -> elbow C-selection -> displacement diagnostic ->
  per-batch checkpoints.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KernelSpec, MachineSpec, MiniBatchConfig,
                        clustering_accuracy, elbow, gamma_from_dmax,
                        mean_displacement, nmi, plan)
from repro.core.minibatch import predict
from repro.data.sampling import split_batches
from repro.data.synthetic import make_md_trajectory
from repro.distributed.outer import DistributedMiniBatchKMeans
from repro.ft.checkpoint import CheckpointManager
from repro.launch.train import build_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=20000)
    ap.add_argument("--atoms", type=int, default=32)
    ap.add_argument("--states", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--restarts", type=int, default=5)
    ap.add_argument("--memory-gb", type=float, default=0.2)
    ap.add_argument("--elbow", action="store_true",
                    help="sweep C over (4, 12) with the elbow criterion")
    args = ap.parse_args(argv)

    mesh = build_mesh(args.mesh)
    x, y = make_md_trajectory(args.frames, args.atoms, args.states,
                              dwell=400.0, seed=0)
    print(f"[md] {args.frames} frames, d={x.shape[1]} "
          f"({args.atoms} atoms), {args.states} metastable states")

    # memory-aware plan (Eq.19): the paper used ~250k-frame mini-batches
    machine = MachineSpec(memory_bytes=args.memory_gb * 1e9,
                          n_processors=len(jax.devices()))
    p = plan(len(x), args.states, machine, d=x.shape[1])
    gamma = gamma_from_dmax(jnp.asarray(x[:4096]))
    print(f"[md] plan: B={p.b} s={p.s} ({p.note}), gamma={gamma:.2e}")

    n_clusters = args.states
    if args.elbow:
        costs = []
        cs = list(range(4, 13, 2))
        for c in cs:
            cfg = MiniBatchConfig(n_clusters=c, n_batches=p.b, s=p.s,
                                  kernel=KernelSpec("rbf", gamma=gamma))
            km = DistributedMiniBatchKMeans(mesh, cfg)
            r = km.fit(split_batches(x, p.b, "stride"))
            costs.append(r.history[-1].cost)
        n_clusters = cs[elbow(costs)]
        print(f"[md] elbow over C={cs}: costs={np.round(costs, 1)} "
              f"-> C*={n_clusters}")

    # 5 restarts, keep minimum cost (paper §4.5)
    best, best_cost = None, np.inf
    t0 = time.time()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        for r in range(args.restarts):
            cfg = MiniBatchConfig(n_clusters=n_clusters, n_batches=p.b,
                                  s=p.s, kernel=KernelSpec("rbf", gamma=gamma),
                                  sampling="stride", seed=r)
            km = DistributedMiniBatchKMeans(mesh, cfg)
            cm = CheckpointManager(f"{ckpt_dir}/run{r}")
            res = km.fit(split_batches(x, p.b, "stride"),
                         checkpoint_cb=lambda s, i: cm.save(i, s))
            cost = res.history[-1].cost
            print(f"[md] restart {r}: final batch cost {cost:.1f}, "
                  f"iters={[h.inner_iters for h in res.history]}")
            if cost < best_cost:
                best, best_cost, best_cfg = res, cost, cfg
    dt = time.time() - t0

    labels = np.asarray(predict(jnp.asarray(x), best.state.medoids,
                                best.state.medoid_diag,
                                spec=best_cfg.kernel))
    disp = mean_displacement(best.history)
    print(f"[md] {args.restarts} restarts in {dt:.1f}s")
    print(f"[md] acc={clustering_accuracy(y, labels):.4f} "
          f"nmi={nmi(y, labels):.4f} (vs {args.states} true states)")
    print(f"[md] displacement/batch (sampling-quality, Fig.4b): "
          f"{np.array2string(disp, precision=4)}")
    # medoids are actual frames -> directly inspectable structures (§4.5)
    print(f"[md] medoid frame norms: "
          f"{np.linalg.norm(np.asarray(best.state.medoids), axis=1).round(1)}")


if __name__ == "__main__":
    main()
