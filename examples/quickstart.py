"""Quickstart: approximate kernel k-means on the paper's 2D toy dataset.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the core public API: MiniBatchConfig knobs (B, s), fitting,
prediction, and the accuracy/NMI metrics — and shows the kernel method
beating linear k-means on a non-linearly-separable variant (two rings).
"""
import numpy as np
import jax.numpy as jnp

from repro.baselines.lloyd import kmeans
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        fit_dataset, nmi)
from repro.core.minibatch import predict
from repro.data.synthetic import toy2d


def xor_blobs(n_per=500, seed=0):
    """XOR arrangement: class 0 at (+,+)/(-,-), class 1 at (+,-)/(-,+).
    No line separates the classes, but the degree-2 polynomial kernel's
    feature map contains x1*x2, which does — the textbook kernel win."""
    rng = np.random.default_rng(seed)
    c = np.array([[2, 2], [-2, -2], [2, -2], [-2, 2]], np.float32)
    x = np.concatenate([rng.normal(ci, 0.5, (n_per, 2)) for ci in c])
    y = np.array([0] * n_per * 2 + [1] * n_per * 2, np.int32)
    perm = rng.permutation(len(x))
    return x[perm].astype(np.float32), y[perm]


def main():
    # ---- paper's 2D toy: 4 gaussians, B = 3 mini-batches -------------------
    x, y = toy2d(n_per_cluster=2500)
    cfg = MiniBatchConfig(n_clusters=4, n_batches=3, s=1.0,
                          kernel=KernelSpec("rbf", gamma=4.0),
                          sampling="stride", seed=0)
    res = fit_dataset(x, cfg)
    labels = np.asarray(predict(jnp.asarray(x), res.state.medoids,
                                res.state.medoid_diag, spec=cfg.kernel))
    print(f"2D toy     | kernel k-means (B=3):   acc={clustering_accuracy(y, labels):.3f} "
          f"nmi={nmi(y, labels):.3f}  inner iters/batch="
          f"{[h.inner_iters for h in res.history]}")

    # ---- sparse centroids: s = 0.2 (5x fewer kernel evaluations) ----------
    cfg_s = MiniBatchConfig(n_clusters=4, n_batches=3, s=0.2,
                            kernel=KernelSpec("rbf", gamma=4.0), seed=0)
    res_s = fit_dataset(x, cfg_s)
    labels_s = np.asarray(predict(jnp.asarray(x), res_s.state.medoids,
                                  res_s.state.medoid_diag, spec=cfg_s.kernel))
    print(f"2D toy     | sparse landmarks (s=.2): acc="
          f"{clustering_accuracy(y, labels_s):.3f} "
          f"nmi={nmi(y, labels_s):.3f}")

    # ---- XOR: kernel vs linear ---------------------------------------------
    xr, yr = xor_blobs()
    lin = kmeans(xr, 2, n_init=5)
    lin_acc = clustering_accuracy(yr, np.asarray(lin.labels))
    spec = KernelSpec("polynomial", gamma=0.25, coef0=0.0, degree=2)
    cfg_r = MiniBatchConfig(n_clusters=2, n_batches=1, s=1.0, kernel=spec,
                            seed=0)
    res_r = fit_dataset(xr, cfg_r)
    lr = np.asarray(predict(jnp.asarray(xr), res_r.state.medoids,
                            res_r.state.medoid_diag, spec=spec))
    print(f"XOR blobs  | linear k-means (C=2):    acc={lin_acc:.3f}")
    print(f"XOR blobs  | poly-2 kernel k-means:   acc="
          f"{clustering_accuracy(yr, lr):.3f}   <- non-linear win")


if __name__ == "__main__":
    main()
