"""Train a model from the zoo end-to-end (driver over repro.launch.train).

CPU-runnable default (reduced olmo config, 100 steps):

    PYTHONPATH=src python examples/train_lm.py

A real ~100M-parameter run (the assignment's "train ~100M for a few hundred
steps") on actual accelerators:

    python examples/train_lm.py --full-100m --steps 300 --mesh 4x2

which trains a 12L/768d/50k-vocab (~100M params) config with checkpoints
every 50 steps and resume-on-restart. The paper's kind is clustering, so the
framework's primary end-to-end example is examples/cluster_md_trajectory.py;
this driver covers the LM-training half of the substrate.
"""
import argparse
import dataclasses
import sys

from repro.configs import get_arch
from repro.configs.base import ModelConfig
from repro.launch import train as train_mod

# ~100M-parameter dense config (olmo-style): 12L x 768d, vocab 50304
LM_100M = dataclasses.replace(
    get_arch("olmo-1b"),
    name="olmo-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_head=64, d_ff=3072)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true",
                    help="real ~100M config instead of the CPU smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    if args.full_100m:
        # register the 100M config under a temporary arch id
        import repro.configs as configs
        mod = type(sys)("olmo_100m")
        mod.FULL = LM_100M
        mod.SMOKE = LM_100M
        configs.ARCHS["olmo-100m"] = mod
        arch_args = ["--arch", "olmo-100m"]
    else:
        arch_args = ["--arch", "olmo-1b", "--smoke"]

    final_loss = train_mod.main(arch_args + [
        "--mesh", args.mesh, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10", "--resume",
    ])
    print(f"[train_lm] final loss {final_loss:.4f} "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
