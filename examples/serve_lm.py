"""Serve a small model with batched requests (continuous batching engine).

    PYTHONPATH=src python examples/serve_lm.py --arch olmo-1b --requests 12

Shows: request submission, mixed prompt lengths decoding in ONE batched
step per tick (per-slot cursors), EOS early-exit, throughput accounting.
"""
import argparse

from repro.launch import serve as serve_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--top-p", type=float, default=0.9)
    args = ap.parse_args(argv)

    serve_mod.main([
        "--arch", args.arch, "--smoke", "--mesh", args.mesh,
        "--requests", str(args.requests), "--max-batch", "4",
        "--max-len", "96", "--max-new-tokens", "12",
        "--top-p", str(args.top_p),
    ])


if __name__ == "__main__":
    main()
