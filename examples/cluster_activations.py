"""LM-integration example: cluster transformer hidden states with the
paper's kernel k-means — the direct analogue of the paper's MD-frame
clustering (conformational frames -> activation vectors; both need no
explicit feature-space geometry, only a kernel).

    PYTHONPATH=src python examples/cluster_activations.py --arch rwkv6-7b

A model from the zoo (reduced config) embeds token sequences drawn from C
distinct synthetic "topics"; the final hidden state of each sequence is a
sample. Kernel k-means on those activations recovers the topics without
labels — the model-zoo and the clustering core composing end-to-end.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import (KernelSpec, MiniBatchConfig, clustering_accuracy,
                        gamma_from_dmax, nmi)
from repro.core.minibatch import fit_dataset, predict
from repro.models import Axes, get_model


def topic_stream(vocab: int, n_topics: int, n_seqs: int, seq_len: int,
                 seed: int = 0):
    """Each topic draws tokens from its own narrow vocabulary band."""
    rng = np.random.default_rng(seed)
    width = max(vocab // (2 * n_topics), 4)
    tokens = np.empty((n_seqs, seq_len), np.int32)
    topics = rng.integers(0, n_topics, n_seqs)
    for i, t in enumerate(topics):
        lo = 1 + t * width
        tokens[i] = rng.integers(lo, lo + width, seq_len)
    return tokens, topics.astype(np.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--topics", type=int, default=5)
    ap.add_argument("--seqs", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=True)
    api = get_model(cfg, tp_size=1)
    params, _ = api.init(jax.random.PRNGKey(0))
    axes = Axes(dp=("data",), tp="model")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    tokens, topics = topic_stream(cfg.vocab_size, args.topics, args.seqs,
                                  args.seq_len)
    print(f"[activations] embedding {args.seqs} sequences with "
          f"{args.arch} (smoke config)")

    # the embedding producer: mean-pooled final hidden state per sequence
    if cfg.family == "ssm":
        from repro.models.rwkv import forward
        fwd = lambda tok: forward(params, tok, cfg, axes, remat=False)[0]  # noqa: E731
    elif cfg.family == "hybrid":
        from repro.models.zamba import forward
        fwd = lambda tok: forward(params, tok, cfg, axes, remat=False)[0]  # noqa: E731
    else:
        from repro.models.transformer import forward
        fwd = lambda tok: forward(params, tok, cfg, axes, remat=False)[0]  # noqa: E731

    feats = []
    with mesh:
        embed = jax.jit(lambda tok: jnp.mean(
            fwd(tok).astype(jnp.float32), axis=1))
        for i in range(0, len(tokens), 64):
            feats.append(np.asarray(embed(jnp.asarray(tokens[i:i + 64]))))
    x = np.concatenate(feats)
    print(f"[activations] features: {x.shape}")

    gamma = gamma_from_dmax(jnp.asarray(x))
    cc = MiniBatchConfig(n_clusters=args.topics, n_batches=args.batches,
                         s=1.0, kernel=KernelSpec("rbf", gamma=gamma),
                         seed=0)
    res = fit_dataset(x, cc)
    labels = np.asarray(predict(jnp.asarray(x), res.state.medoids,
                                res.state.medoid_diag, spec=cc.kernel))
    print(f"[activations] kernel k-means over activations: "
          f"acc={clustering_accuracy(topics, labels):.3f} "
          f"nmi={nmi(topics, labels):.3f} "
          f"(B={args.batches} mini-batches)")


if __name__ == "__main__":
    main()
